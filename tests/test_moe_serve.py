"""MoE dispatch correctness + serve sharding-plan invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")


class TestMoEDispatch:
    def _setup(self, T=24, D=16, E=4, k=2, Fe=32, cf=8.0, seed=0):
        from repro.models.moe import MoESpec
        ks = jax.random.split(jax.random.key(seed), 5)
        spec = MoESpec(num_experts=E, top_k=k, d_ff_expert=Fe,
                       capacity_factor=cf)
        p = {
            "router": jax.random.normal(ks[0], (D, E)),
            "wg": jax.random.normal(ks[1], (E, D, Fe)) / np.sqrt(D),
            "wu": jax.random.normal(ks[2], (E, D, Fe)) / np.sqrt(D),
            "wo": jax.random.normal(ks[3], (E, Fe, D)) / np.sqrt(Fe),
        }
        x = jax.random.normal(ks[4], (T, D))
        return spec, p, x

    def test_matches_dense_reference(self):
        """With drop-free capacity, gather/scatter dispatch == dense
        (every-expert) computation weighted by the router."""
        from repro.models.moe import moe_ffn
        spec, p, x = self._setup()
        y, aux = moe_ffn(p, x, spec)

        # dense reference
        logits = x @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        topw, topi = jax.lax.top_k(probs, spec.top_k)
        topw = topw / topw.sum(-1, keepdims=True)
        h = jnp.einsum("td,edf->tef", x, p["wg"])
        u = jnp.einsum("td,edf->tef", x, p["wu"])
        eo = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, p["wo"])
        want = jnp.zeros_like(x)
        for slot in range(spec.top_k):
            w = topw[:, slot][:, None]
            want = want + w * jnp.take_along_axis(
                eo, topi[:, slot][:, None, None].repeat(eo.shape[-1], -1),
                axis=1)[:, 0]
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)
        assert float(aux) > 0

    def test_capacity_drops_reduce_output(self):
        from repro.models.moe import moe_ffn
        spec, p, x = self._setup(cf=8.0)
        y_full, _ = moe_ffn(p, x, spec)
        y_drop, _ = moe_ffn(p, x, spec._replace(capacity_factor=0.25))
        # dropped tokens get zero contribution -> outputs differ
        assert float(jnp.abs(y_full - y_drop).max()) > 1e-4

    def test_shared_experts_always_on(self):
        from repro.models.moe import MoESpec, moe_ffn
        spec, p, x = self._setup()
        spec = spec._replace(num_shared=1)
        Fe, D = spec.d_ff_expert, x.shape[1]
        kk = jax.random.split(jax.random.key(9), 3)
        p["shared_wg"] = jax.random.normal(kk[0], (D, Fe)) / np.sqrt(D)
        p["shared_wu"] = jax.random.normal(kk[1], (D, Fe)) / np.sqrt(D)
        p["shared_wo"] = jax.random.normal(kk[2], (Fe, D)) / np.sqrt(Fe)
        y_shared, _ = moe_ffn(p, x, spec)
        y_plain, _ = moe_ffn(p, x, spec._replace(num_shared=0))
        from repro.models.layers import gated_mlp
        want = y_plain + gated_mlp(
            {"wi_gate": p["shared_wg"], "wi_up": p["shared_wu"],
             "wo": p["shared_wo"]}, x)
        np.testing.assert_allclose(np.asarray(y_shared), np.asarray(want),
                                   rtol=2e-4, atol=2e-5)


class TestServePlan:
    def test_cache_specs_shard_seq_over_model(self):
        """Flash-decoding layout: batch over dp, cache seq over model."""
        import os
        import subprocess
        import sys
        import textwrap
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(root, "src")
        env["JAX_PLATFORMS"] = "cpu"
        prog = textwrap.dedent("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import get_smoke_config
        from repro.models import LM
        from repro.serve.step import plan_serve_sharding
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        model = LM(get_smoke_config("gemma2-9b"))
        ap = jax.eval_shape(model.init, jax.random.key(0))
        ac = jax.eval_shape(lambda: model.init_cache(8, 64))
        plan = plan_serve_sharding(model, ap, ac, mesh)
        # find an attention K cache leaf: (reps, B, C, KV, hd)
        leaves = jax.tree_util.tree_leaves_with_path(plan.cache_specs)
        ks = [(jax.tree_util.keystr(p), s) for p, s in leaves
              if "'k'" in jax.tree_util.keystr(p)]
        assert ks, leaves
        for name, spec in ks:
            assert spec[1] == "data", (name, spec)   # batch over dp
            assert spec[2] == "model", (name, spec)  # seq over model
        print("PLAN OK")
        """)
        out = subprocess.run([sys.executable, "-c", prog], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "PLAN OK" in out.stdout

    def test_seq_sharded_decode_matches_unsharded(self):
        """seq_sharded=True (long-context layout: the cache SEQUENCE dim
        sharded over data x model, batch replicated) must decode the same
        logits as the plain single-device path — XLA's derived
        distributed softmax is a pure layout change."""
        import os
        import subprocess
        import sys
        import textwrap
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        env["PYTHONPATH"] = os.path.join(root, "src")
        env["JAX_PLATFORMS"] = "cpu"
        prog = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs.base import get_smoke_config
        from repro.models import LM
        from repro.serve.step import make_serve_step, plan_serve_sharding

        model = LM(get_smoke_config("lm-100m"))
        params = jax.jit(model.init)(jax.random.key(0))
        params = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        B, C, S = 1, 64, 6
        toks = jax.random.randint(jax.random.key(1), (B, S), 0,
                                  model.cfg.vocab_size)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cache = model.init_cache(B, C)
        plan = plan_serve_sharding(model, jax.eval_shape(lambda: params),
                                   jax.eval_shape(lambda: cache), mesh,
                                   seq_sharded=True)
        # the long-context layout: cache seq over BOTH axes, batch
        # replicated (batch_dp=False)
        kspecs = [s for p, s in
                  jax.tree_util.tree_leaves_with_path(plan.cache_specs)
                  if "'k'" in jax.tree_util.keystr(p)]
        assert kspecs and all(s[2] == ("data", "model") for s in kspecs), \\
            kspecs
        step = make_serve_step(model, mesh, plan, batch_dp=False)
        sh_lg = []
        for i in range(S):
            lg, cache = step(params, cache, toks[:, i][:, None],
                             jnp.int32(i))
            sh_lg.append(np.asarray(lg[:, -1], np.float32))

        ref_cache = model.init_cache(B, C)
        for i in range(S):
            lg, ref_cache = model.decode_step(params, ref_cache,
                                              toks[:, i][:, None],
                                              jnp.int32(i))
            got = sh_lg[i]
            want = np.asarray(lg[:, -1], np.float32)
            # bf16 matmuls accumulate in a different (sharded) order, so
            # compare absolutely at the bf16 resolution of the logits
            np.testing.assert_allclose(got, want, rtol=0, atol=0.1)
            assert np.array_equal(got.argmax(-1), want.argmax(-1)), i
        print("SEQ-SHARDED OK")
        """)
        out = subprocess.run([sys.executable, "-c", prog], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "SEQ-SHARDED OK" in out.stdout
