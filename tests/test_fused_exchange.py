"""Fused flat-buffer exchange engine: layout round-trips, bit-level
equivalence with the per-leaf exchange under fp, quantization-variance
agreement under orq-9/terngrad, the error-feedback residual path, and the
O(1)-collectives-per-step guarantee.

Multi-device cases run in subprocesses with XLA_FLAGS forcing 8 host
devices (the main test process must keep the default single-device view,
per the repo's dry-run-only rule for fake device counts); 1-device-mesh
cases run in-process.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import comm, make_quantizer

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n_devices: int = 8) -> str:
    prog = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def _ragged_tree(key, dtype_b=jnp.bfloat16):
    """Pytree with ragged leaf sizes, a non-f32 leaf, and a scalar — the
    shapes the per-leaf path paid padding for on every leaf."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w": jax.random.normal(k1, (33, 7)),
        "b": jax.random.normal(k2, (40,)).astype(dtype_b),
        "m": {"u": jax.random.normal(k3, (3, 5, 2)),
              "s": jax.random.normal(k4, ())},
    }


class TestGradLayout:
    def test_flatten_unflatten_bitexact(self):
        tree = _ragged_tree(jax.random.key(0))
        layout = comm.GradLayout.from_tree(tree)
        assert layout.size == 33 * 7 + 40 + 3 * 5 * 2 + 1
        buf = layout.flatten(tree)
        assert buf.shape == (layout.size,) and buf.dtype == jnp.float32
        back = layout.unflatten(buf)
        for want, got in zip(jax.tree_util.tree_leaves(tree),
                             jax.tree_util.tree_leaves(back)):
            assert got.dtype == want.dtype and got.shape == want.shape
            np.testing.assert_array_equal(np.asarray(got, np.float32),
                                          np.asarray(want, np.float32))

    def test_unflatten_f32_residuals(self):
        tree = _ragged_tree(jax.random.key(1))
        layout = comm.GradLayout.from_tree(tree)
        res = layout.unflatten(layout.flatten(tree), restore_dtype=False)
        assert all(x.dtype == jnp.float32
                   for x in jax.tree_util.tree_leaves(res))

    def test_leaf_slice_matches_offsets(self):
        tree = _ragged_tree(jax.random.key(2))
        layout = comm.GradLayout.from_tree(tree)
        buf = layout.flatten(tree)
        leaves = jax.tree_util.tree_leaves(tree)
        for i, want in enumerate(leaves):
            got = layout.leaf_slice(buf, i)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(want, np.float32))

    def test_from_abstract_tree(self):
        tree = _ragged_tree(jax.random.key(3))
        ab = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        assert comm.GradLayout.from_tree(ab) == comm.GradLayout.from_tree(tree)

    def test_padded_size(self):
        layout = comm.GradLayout.from_tree({"a": jnp.zeros(1000)})
        # 8 workers, bucket 64: chunk=125 -> pad to 128 -> 1024 total
        assert layout.padded_size(8, 64) == 1024
        assert layout.padded_size(1, 2048) == 1000


class TestEngineStatics:
    def test_spans(self):
        eng = comm.GradientExchange(make_quantizer("orq-9"), ("data",),
                                    max_chunk_elems=100)
        assert eng.spans(250) == [(0, 100), (100, 200), (200, 250)]
        assert eng.spans(90) == [(0, 90)]
        none = comm.GradientExchange(make_quantizer("orq-9"), ("data",))
        assert none.spans(10 ** 9) == [(0, 10 ** 9)]

    def test_collective_launches_o1(self):
        qz = make_quantizer("orq-9")
        eng = comm.GradientExchange(qz, ("data",))
        # 2 all_to_all (phase 1) + 2 all_gather (phase 2 requant),
        # regardless of n
        assert eng.collective_launches(10 ** 3) == 4
        assert eng.collective_launches(10 ** 9) == 4
        norq = comm.GradientExchange(qz, ("data",), server_requant=False)
        assert norq.collective_launches(10 ** 9) == 3
        fp = comm.GradientExchange(make_quantizer("fp"), ("data",))
        assert fp.collective_launches(10 ** 9) == 1
        chunked = comm.GradientExchange(qz, ("data",),
                                        max_chunk_elems=10 ** 6)
        assert chunked.collective_launches(10 ** 7) == 40  # 10 spans * 4

    def test_fused_beats_per_leaf_accounting(self):
        qz = make_quantizer("orq-9", bucket_size=512)
        sizes = [7, 131, 2048, 100_000] + [33] * 60   # many tiny leaves
        pl_launch, pl_bytes = comm.per_leaf_stats(qz, sizes, 8)
        f_launch, f_bytes = comm.fused_stats(qz, sizes, 8)
        assert f_launch == 4 and pl_launch == 4 * len(sizes)
        assert f_bytes < pl_bytes   # shared buckets amortize ragged tails

    def test_qdq_local_flat_fused(self):
        flat = jax.random.laplace(jax.random.key(5), (5000,)) * 0.01
        qz = make_quantizer("orq-9", bucket_size=512)
        eng = comm.GradientExchange(qz, ())
        np.testing.assert_array_equal(
            np.asarray(eng.qdq_local_flat(flat, jax.random.key(1))),
            np.asarray(qz.qdq(flat, jax.random.key(1))))


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.core import make_quantizer, comm
from repro.utils.compat import shard_map

mesh = jax.make_mesh((8,), ("data",))
DP = ("data",)
L = 8

def shmap(f, in_specs, out_specs):
    return jax.jit(shard_map(f, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, axis_names={"data"}, check_vma=False))

def ragged_tree(key, scale=0.1):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "w": jax.random.laplace(k1, (L, 33, 7)) * scale,
        "b": jax.random.laplace(k2, (L, 40)) * scale,
        "m": {"u": jax.random.laplace(k3, (L, 3, 5, 2)) * scale,
              "s": jax.random.laplace(k4, (L, 1)) * scale},
    }

def worker_slice(tree):
    return jax.tree_util.tree_map(lambda x: x[0], tree)

def leaf_key(path):
    import zlib
    return zlib.crc32(path.encode()) & 0x7FFFFFFF

IN = jax.tree_util.tree_map(lambda x: P("data", *([None] * (x.ndim - 1))),
                            {"w": jnp.zeros((L, 1, 1)), "b": jnp.zeros((L, 1)),
                             "m": {"u": jnp.zeros((L, 1, 1, 1)),
                                   "s": jnp.zeros((L, 1))}})
"""


def test_fp_fused_leaf_slices_bitexact_vs_per_leaf():
    """Under fp both paths are exact means — the fused unflatten's leaf
    slices must equal the per-leaf exchange bit for bit (8 workers)."""
    run_devices(COMMON + """
tree = ragged_tree(jax.random.key(0))
eng = comm.GradientExchange(make_quantizer("fp"), DP)

def f(t):
    t = worker_slice(t)
    fused = eng.exchange(t, jax.random.key(1))
    perleaf = jax.tree_util.tree_map(
        lambda g: comm.quantized_all_reduce_mean(
            g.reshape(-1), make_quantizer("fp"), jax.random.key(1), DP
        ).reshape(g.shape), t)
    return jax.tree_util.tree_map(lambda a, b: (a - b)[None], fused, perleaf)

out = shmap(f, (IN,), IN)(tree)
for leaf in jax.tree_util.tree_leaves(out):
    assert np.asarray(leaf).max() == 0.0 and np.asarray(leaf).min() == 0.0
print("FP-BITEXACT OK")
""")
    # output asserted inside the subprocess


def test_quantized_fused_vs_per_leaf_within_variance():
    """orq-9 / terngrad: fused and per-leaf exchanges both sit within
    quantization variance of the true mean, and of each other."""
    run_devices(COMMON + """
tree = ragged_tree(jax.random.key(2))
true_mean = jax.tree_util.tree_map(lambda x: np.asarray(x.mean(0)), tree)

for name, tol in [("orq-9", 0.05), ("terngrad", 0.12)]:
    qz = make_quantizer(name, bucket_size=64)
    eng = comm.GradientExchange(qz, DP)

    def f(t):
        t = worker_slice(t)
        fused = eng.exchange(t, jax.random.key(3))
        perleaf = jax.tree_util.tree_map(
            lambda g: comm.quantized_all_reduce_mean(
                g.reshape(-1), qz, jax.random.key(3), DP).reshape(g.shape), t)
        return (jax.tree_util.tree_map(lambda a: a[None], fused),
                jax.tree_util.tree_map(lambda a: a[None], perleaf))

    fused, perleaf = shmap(f, (IN,), (IN, IN))(tree)
    for fu, pl, tm in zip(jax.tree_util.tree_leaves(fused),
                          jax.tree_util.tree_leaves(perleaf),
                          jax.tree_util.tree_leaves(true_mean)):
        fu, pl = np.asarray(fu)[0], np.asarray(pl)[0]
        # identical on every worker already checked by decode determinism
        assert np.abs(fu - tm).mean() < tol, (name, np.abs(fu - tm).mean())
        assert np.abs(pl - tm).mean() < tol, (name, np.abs(pl - tm).mean())
        assert np.abs(fu - pl).mean() < 2 * tol
    print(name, "VARIANCE OK")
""")


def test_fused_identical_across_workers_and_chunked():
    """Deterministic phase-2 decode keeps every worker bit-identical, with
    and without size-capped chunking; chunked fp stays exact."""
    run_devices(COMMON + """
tree = ragged_tree(jax.random.key(4))
flat_sz = 33*7 + 40 + 3*5*2 + 1

for name, cap in [("orq-9", None), ("orq-9", 97), ("fp", 97)]:
    qz = make_quantizer(name, bucket_size=64)
    eng = comm.GradientExchange(qz, DP, max_chunk_elems=cap)

    def f(t):
        t = worker_slice(t)
        layout = comm.GradLayout.from_tree(t)
        out = eng.exchange_flat(layout.flatten(t), jax.random.key(5))
        return out[None]

    got = np.asarray(shmap(f, (IN,), P("data", None))(tree))
    assert got.shape == (L, flat_sz)
    for w in range(1, L):
        np.testing.assert_array_equal(got[0], got[w])
    if name == "fp":
        layout = comm.GradLayout.from_tree(worker_slice(tree))
        want = np.asarray(layout.flatten(jax.tree_util.tree_map(
            lambda x: x.mean(0), tree)))
        np.testing.assert_allclose(got[0], want, rtol=1e-6, atol=1e-7)
    print(name, cap, "IDENTICAL OK")
""")


def test_ef_residual_fused_layout():
    """local_qdq_flat must be bit-consistent with the fused collective:
    the across-worker mean of each worker's local decode equals the
    exchange result when the server skips re-quantization."""
    run_devices(COMMON + """
tree = ragged_tree(jax.random.key(6))
qz = make_quantizer("orq-5", bucket_size=64)
eng = comm.GradientExchange(qz, DP, server_requant=False)

def f(t):
    t = worker_slice(t)
    layout = comm.GradLayout.from_tree(t)
    flat = layout.flatten(t)
    key = jax.random.key(7)
    local = eng.local_qdq_flat(flat, key)
    mean = eng.exchange_flat(flat, key)
    resid = flat - local        # the EF residual the train step stores
    return local[None], mean[None], resid[None]

spec = P("data", None)
local, mean, resid = shmap(f, (IN,), (spec, spec, spec))(tree)
local, mean, resid = map(np.asarray, (local, mean, resid))
# bit-consistency: mean over workers of local decodes == collective mean
np.testing.assert_allclose(local.mean(0), mean[0], rtol=1e-5, atol=1e-6)
# residual really is gradient minus own contribution
layout = comm.GradLayout.from_tree(worker_slice(tree))
flat0 = np.asarray(layout.flatten(worker_slice(tree)))
np.testing.assert_allclose(resid[0], flat0 - local[0], rtol=1e-6, atol=1e-7)
assert np.abs(resid).max() > 0   # quantization error is nonzero
print("EF-FUSED OK")
""")


def test_single_device_mesh_fused_matches_local_qdq():
    """On a 1-device mesh (L=1) the phase-1 'mean' is the worker's own
    dequantized buffer: exchange(server_requant=False) == local_qdq, bit
    for bit — in-process, default device view."""
    from repro.utils.compat import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    qz = make_quantizer("orq-9", bucket_size=128)
    eng = comm.GradientExchange(qz, ("data",), server_requant=False)
    flat = jax.random.laplace(jax.random.key(8), (1, 999)) * 0.1

    def f(x):
        x = x[0]
        key = jax.random.key(9)
        return (eng.exchange_flat(x, key)[None],
                eng.local_qdq_flat(x, key)[None])

    fn = jax.jit(shard_map(f, mesh=mesh, in_specs=(P("data", None),),
                           out_specs=(P("data", None), P("data", None)),
                           axis_names={"data"}, check_vma=False))
    mean, local = fn(flat)
    np.testing.assert_array_equal(np.asarray(mean), np.asarray(local))


@pytest.mark.slow
def test_train_step_collective_count_o1():
    """Acceptance: the replicated-mode train step issues O(1) quantized
    collectives per step when fused (not O(num_leaves)). The fused leg is
    enforced through the SAME collective-budget rule the CI matrix audit
    runs, with expectations derived from the step's own exchange
    engines; the per-leaf leg shows the contrast."""
    from repro.analysis import TraceBundle, run_checks, stats
    from repro.analysis.audit import expected_train_collectives
    from repro.configs.base import get_smoke_config
    from repro.core import QuantConfig
    from repro.data import SyntheticLM
    from repro.models import LM
    from repro.optim.schedule import constant_lr
    from repro.train import TrainConfig, make_train_step
    from repro.train.step import exchange_engines, init_state

    cfg = get_smoke_config("lm-100m")
    model = LM(cfg)
    mesh = jax.make_mesh((1,), ("data",))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=2,
                       seed=0)
    n_leaves = len(jax.tree_util.tree_leaves(
        jax.eval_shape(model.init, jax.random.key(0))))
    assert n_leaves >= 10

    closed = {}
    for fused in (True, False):
        tcfg = TrainConfig(policy=QuantConfig(name="orq-9", bucket_size=512),
                           mode="replicated", fused_exchange=fused)
        state = init_state(model, mesh, tcfg, jax.random.key(0))
        step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
        closed[fused] = jax.make_jaxpr(step_fn)(state, data.batch(0),
                                                jax.random.key(1))

    # fused: exactly one payload + one level-table all_to_all (phase 1)
    # and two all_gathers (phase 2 re-quant), whatever the leaf count
    tcfg = TrainConfig(policy=QuantConfig(name="orq-9", bucket_size=512),
                       mode="replicated", fused_exchange=True)
    meta = expected_train_collectives(
        exchange_engines(model, mesh, tcfg), mesh, tcfg.pipeline_chunks)
    assert meta["expected_collectives"][("all_to_all", ("data",))] == 2, meta
    assert meta["expected_collectives"][("all_gather", ("data",))] == 2, meta
    fs = run_checks(
        [TraceBundle(label="fused-o1", kind="train_step",
                     closed=closed[True], meta=meta)],
        rules=["collective-budget"])
    assert not fs, [str(f) for f in fs]

    # per-leaf: one exchange per leaf
    leaf = stats.collective_axis_counts(closed[False])
    assert stats.axis_collectives(
        leaf, "all_to_all", ("data",)) == 2 * n_leaves, (leaf, n_leaves)
    assert stats.axis_collectives(
        leaf, "all_gather", ("data",)) == 2 * n_leaves, (leaf, n_leaves)
