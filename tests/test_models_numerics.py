"""Numerical equivalence tests for the model substrate's optimized paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")


class TestChunkedWKV:
    def _inputs(self, seed=0, B=2, S=50, H=3, hd=8):
        ks = jax.random.split(jax.random.key(seed), 6)
        r = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, H, hd))
        v = jax.random.normal(ks[2], (B, S, H, hd))
        dec = jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 1.0
        u = jax.random.normal(ks[4], (H, hd))
        s0 = jax.random.normal(ks[5], (B, H, hd, hd)) * 0.1
        return r, k, v, dec, u, s0

    @pytest.mark.parametrize("chunk", [4, 16, 64])
    @pytest.mark.parametrize("S", [1, 7, 50, 64])
    def test_matches_sequential(self, chunk, S):
        from repro.models.rwkv import _wkv_scan, _wkv_scan_sequential
        r, k, v, dec, u, s0 = self._inputs(S=S)
        w = jnp.exp(-jnp.exp(dec))
        o1, s1 = _wkv_scan_sequential(r, k, v, w, u, chunk, s0)
        o2, s2 = _wkv_scan(r, k, v, None, u, chunk, s0,
                           logw=-jnp.exp(dec))
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   atol=2e-4, rtol=1e-4)

    def test_extreme_decay_stable(self):
        from repro.models.rwkv import _wkv_scan, _wkv_scan_sequential
        r, k, v, _, u, s0 = self._inputs()
        dec = jnp.full(r.shape, 2.5)  # log w ~ -12/token
        w = jnp.exp(-jnp.exp(dec))
        o1, _ = _wkv_scan_sequential(r, k, v, w, u, 16, s0)
        o2, _ = _wkv_scan(r, k, v, None, u, 16, s0, logw=-jnp.exp(dec))
        assert bool(jnp.isfinite(o2).all())
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   atol=2e-4, rtol=1e-4)

    def test_gradients_flow(self):
        from repro.models.rwkv import _wkv_scan
        r, k, v, dec, u, s0 = self._inputs(S=32)

        def loss(k):
            o, _ = _wkv_scan(r, k, v, None, u, 8, s0, logw=-jnp.exp(dec))
            return (o ** 2).sum()

        g = jax.grad(loss)(k)
        assert bool(jnp.isfinite(g).all())
        assert float(jnp.abs(g).max()) > 0


class TestMambaChunking:
    def test_forward_matches_unchunked(self):
        """Chunked scan-project == one-chunk reference."""
        import dataclasses
        from repro.models.ssm import MambaSpec, mamba_forward
        from repro.models.blocks import init_layer, LayerSpec
        from repro.configs.base import get_smoke_config

        cfg = get_smoke_config("jamba-v0.1-52b")
        spec = LayerSpec(kind="mamba", moe=False, d_ff=cfg.d_ff)
        p = init_layer(cfg, spec, jax.random.key(0))
        p = {k: v for k, v in p.items() if k != "norm"}
        x = jax.random.normal(jax.random.key(1), (2, 40, cfg.d_model)) * 0.1
        ms_small = MambaSpec(d_model=cfg.d_model,
                             d_state=cfg.mamba.d_state,
                             d_conv=cfg.mamba.d_conv,
                             expand=cfg.mamba.expand, chunk=8)
        ms_big = ms_small._replace(chunk=64)
        y1 = mamba_forward(p, x, ms_small)
        y2 = mamba_forward(p, x, ms_big)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   atol=2e-3, rtol=1e-2)

    def test_decode_matches_forward(self):
        """One-step decode chain reproduces the chunked training forward."""
        from repro.models.ssm import (init_mamba_state, mamba_decode_step,
                                      mamba_forward, MambaSpec)
        from repro.models.blocks import init_layer, LayerSpec
        from repro.configs.base import get_smoke_config

        cfg = get_smoke_config("jamba-v0.1-52b")
        spec = LayerSpec(kind="mamba", moe=False, d_ff=cfg.d_ff)
        p = init_layer(cfg, spec, jax.random.key(0))
        p = {k: v for k, v in p.items() if k != "norm"}
        ms = MambaSpec(d_model=cfg.d_model, d_state=cfg.mamba.d_state,
                       d_conv=cfg.mamba.d_conv, expand=cfg.mamba.expand,
                       chunk=4)
        x = jax.random.normal(jax.random.key(2), (1, 12, cfg.d_model)) * 0.1
        y_train = mamba_forward(p, x, ms)
        st = init_mamba_state(1, ms, jnp.float32)
        outs = []
        for i in range(12):
            y, st = mamba_decode_step(p, x[:, i:i + 1], st, ms)
            outs.append(y)
        y_dec = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                                   atol=2e-3, rtol=1e-2)
