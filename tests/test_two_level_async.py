"""Temporal hierarchy (two_level_async) suite (PR tentpole).

Covers: hierarchy resolution (the degenerate H=1 window IS two_level);
TrainConfig validation; the ``sync_every`` per-link accounting (exactly
H-fold fewer quantized DCN bytes/step, inner fp intra all-reduce added
to ICI); and, in 8-fake-device subprocesses: H=1 bit-identity to
two_level, the H=4 window's pod divergence between syncs + global
reconvergence at syncs, mid-window checkpoint/resume reproducing the
next outer sync bit-for-bit, and the traced collective split (inner
step wire-silent, sync step's quantized traffic on the pod axis only).

Multi-device cases run in subprocesses with XLA_FLAGS forcing 8 host
devices (the main test process must keep the default single-device
view, per the repo's dry-run-only rule for fake device counts).
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.core import comm, make_quantizer
from repro.train import TrainConfig

jax.config.update("jax_platform_name", "cpu")

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_devices(body: str, n_devices: int = 8) -> str:
    prog = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


class TestResolve:
    def test_registered(self):
        assert "two_level_async" in comm.HIERARCHIES

    def test_h1_resolves_to_two_level(self):
        # the degenerate window is not "similar to" two_level — it IS the
        # two_level code path, so H=1 bit-identity holds by construction
        assert comm.resolve_hierarchy("two_level_async", ("pod", "data"),
                                      local_steps=1) == "two_level"

    def test_h_gt_1_stays_async(self):
        assert comm.resolve_hierarchy("two_level_async", ("pod", "data"),
                                      local_steps=4) == "two_level_async"

    def test_auto_never_picks_async(self):
        assert comm.resolve_hierarchy("auto", ("pod", "data"),
                                      local_steps=4) == "two_level"
        assert comm.resolve_hierarchy("auto", ("data",),
                                      local_steps=4) == "flat"

    def test_split_degrades_async_to_two_level(self):
        assert comm.split_dp_axes(("pod", "data"), "two_level_async") == \
            (("data",), ("pod",))


class TestConfigValidation:
    def test_local_steps_lower_bound(self):
        with pytest.raises(ValueError, match="local_steps"):
            TrainConfig(policy="orq-9", local_steps=0)

    def test_local_steps_need_async_hierarchy(self):
        with pytest.raises(ValueError, match="two_level_async"):
            TrainConfig(policy="orq-9", hierarchy="two_level",
                        local_steps=4)

    def test_async_rejects_fsdp(self):
        with pytest.raises(ValueError, match="replicated"):
            TrainConfig(policy="orq-9", mode="fsdp",
                        hierarchy="two_level_async", local_steps=4)

    def test_async_rejects_per_leaf(self):
        with pytest.raises(ValueError, match="fused_exchange"):
            TrainConfig(policy="orq-9", mode="replicated",
                        hierarchy="two_level_async", local_steps=4,
                        fused_exchange=False)

    def test_bad_outer_optimizer(self):
        with pytest.raises(ValueError, match="outer_optimizer"):
            TrainConfig(policy="orq-9", mode="replicated",
                        hierarchy="two_level_async", local_steps=4,
                        outer_optimizer="adamw")

    def test_valid_async_config(self):
        tcfg = TrainConfig(policy="orq-9", mode="replicated",
                           hierarchy="two_level_async", local_steps=4)
        assert tcfg.outer_optimizer == "nesterov"
        assert tcfg.outer_lr == 0.7 and tcfg.outer_momentum == 0.9


class TestSyncEveryAccounting:
    def test_dcn_bytes_drop_exactly_h_fold(self):
        qz = make_quantizer("orq-9", bucket_size=512)
        n = 10_000_000
        base = comm.link_stats(qz, n, n_intra=16, n_inter=2,
                               two_level=True)
        for h in (2, 4, 8):
            st = comm.link_stats(qz, n, n_intra=16, n_inter=2,
                                 two_level=True, sync_every=h)
            assert st["dcn_q_bytes"] == pytest.approx(
                base["dcn_q_bytes"] / h)
            assert st["dcn_bytes"] == pytest.approx(
                base["dcn_bytes"] / h)

    def test_inner_fp_allreduce_lands_on_ici(self):
        qz = make_quantizer("orq-9", bucket_size=512)
        n, n_intra, h = 1_000_000, 16, 4
        base = comm.link_stats(qz, n, n_intra=n_intra, n_inter=2,
                               two_level=True)
        st = comm.link_stats(qz, n, n_intra=n_intra, n_inter=2,
                             two_level=True, sync_every=h)
        inner = 8.0 * n * (n_intra - 1) / n_intra
        assert st["ici_bytes"] == pytest.approx(
            base["ici_bytes"] / h + inner)
        assert st["launches"] == pytest.approx(base["launches"] / h + 1)

    def test_sync_every_one_is_identity(self):
        qz = make_quantizer("orq-9", bucket_size=512)
        a = comm.link_stats(qz, 10_000, n_intra=4, n_inter=2,
                            two_level=True)
        b = comm.link_stats(qz, 10_000, n_intra=4, n_inter=2,
                            two_level=True, sync_every=1)
        assert a == b

    def test_sync_every_validated(self):
        qz = make_quantizer("orq-9", bucket_size=512)
        with pytest.raises(ValueError, match="sync_every"):
            comm.link_stats(qz, 100, n_intra=2, n_inter=2,
                            two_level=True, sync_every=0)

    def test_single_pod_inner_adds_no_ici(self):
        # n_intra=1: there is no intra axis, so amortization divides
        # everything and adds nothing
        qz = make_quantizer("orq-9", bucket_size=512)
        base = comm.link_stats(qz, 10_000, n_intra=1, n_inter=8,
                               two_level=False)
        st = comm.link_stats(qz, 10_000, n_intra=1, n_inter=8,
                             two_level=False, sync_every=4)
        assert st["ici_bytes"] == pytest.approx(base["ici_bytes"] / 4)
        assert st["launches"] == pytest.approx(base["launches"] / 4)

    def test_policy_link_stats_passthrough(self):
        from repro.core import QuantPolicy
        policy = QuantPolicy.parse("norm=fp,default=orq-9",
                                   bucket_size=512)
        ps = [("norm", 1000), ("w", 100_000)]
        base, _ = comm.policy_link_stats(policy, ps, n_intra=4, n_inter=2,
                                         two_level=True)
        st, _ = comm.policy_link_stats(policy, ps, n_intra=4, n_inter=2,
                                       two_level=True, sync_every=4)
        assert st["dcn_q_bytes"] == pytest.approx(base["dcn_q_bytes"] / 4)


COMMON = """
import hashlib
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_smoke_config
from repro.data import SyntheticLM
from repro.models import LM
from repro.optim.schedule import constant_lr
from repro.train import AsyncTrainStep, TrainConfig, make_train_step
from repro.train.step import init_state

def digest(tree):
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(tree):
        h.update(np.asarray(jax.device_get(leaf)).tobytes())
    return h.hexdigest()

cfg = get_smoke_config("lm-100m")
model = LM(cfg)
mesh = jax.make_mesh((2, 4), ("pod", "data"))
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, batch_size=8,
                   seed=3)
POLICY = "norm|bias=fp,default=orq-9"
"""


def test_async_h1_bit_identical_to_two_level():
    """Acceptance: two_level_async(H=1) must be BIT-IDENTICAL to
    two_level on the same (2, 4) pod x data mesh — same program (the
    resolution collapses the degenerate window), same losses, same
    params/opt/EF after several steps."""
    run_devices(COMMON + """
out = {}
for hier, h in (("two_level", 1), ("two_level_async", 1)):
    tcfg = TrainConfig(policy=POLICY, mode="replicated", hierarchy=hier,
                       local_steps=h, error_feedback=True)
    state = init_state(model, mesh, tcfg, jax.random.key(0))
    step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
    assert not isinstance(step_fn, AsyncTrainStep), hier
    losses = []
    for i in range(4):
        state, m = step_fn(state, data.batch(i), jax.random.key(42))
        losses.append(float(m["loss"]))
    out[hier] = (losses, digest((state.params, state.opt, state.ef)))
assert out["two_level"] == out["two_level_async"], out
print("H1-BITEXACT OK", out["two_level"][1][:12])
""")


def test_async_h4_window_divergence_and_sync():
    """The H=4 window's contract on the stacked state: params diverge
    across pods during inner steps (each pod optimizes locally), every
    sync step makes them globally identical again AND equal to the new
    outer anchor; anchor/momentum only move at sync steps; loss
    decreases over the run."""
    run_devices(COMMON + """
H = 4
tcfg = TrainConfig(policy=POLICY, mode="replicated",
                   hierarchy="two_level_async", local_steps=H,
                   error_feedback=True)
state = init_state(model, mesh, tcfg, jax.random.key(0))
step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
assert isinstance(step_fn, AsyncTrainStep)

def pod_views(state):
    # stacked leading worker axis: rows 0..3 = pod 0, rows 4..7 = pod 1
    leaves = [np.asarray(jax.device_get(x))
              for x in jax.tree_util.tree_leaves(state.params)]
    return ([l[0] for l in leaves], [l[4] for l in leaves])

losses = []
for i in range(2 * H):
    is_sync = step_fn.is_sync_step(int(state.step))
    assert is_sync == ((i + 1) % H == 0), i
    anchor_before = digest(state.outer.anchor)
    state, m = step_fn(state, data.batch(i), jax.random.key(42))
    losses.append(float(m["loss"]))
    p0, p1 = pod_views(state)
    diverged = any(not np.array_equal(a, b) for a, b in zip(p0, p1))
    if is_sync:
        assert not diverged, f"step {i}: pods differ AFTER sync"
        # the agreed params ARE the new anchor (next window's start)
        anchors = [np.asarray(jax.device_get(x)) for x in
                   jax.tree_util.tree_leaves(state.outer.anchor)]
        for a, p in zip(anchors, p0):
            np.testing.assert_array_equal(a, p)
        assert digest(state.outer.anchor) != anchor_before, i
    else:
        assert diverged, f"step {i}: pods identical mid-window"
        assert digest(state.outer.anchor) == anchor_before, i
assert losses[-1] < losses[0], losses
print("H4-WINDOW OK", losses)
""")


def test_async_mid_window_checkpoint_resume_bit_exact():
    """ISSUE satellite: save the full TrainState at inner step k < H,
    restore it, and the next outer sync (and everything after) must be
    bit-for-bit what the uninterrupted run produced."""
    run_devices(COMMON + """
from repro.checkpoint import load_checkpoint, save_checkpoint
import os, tempfile

H, SAVE_AT, TOTAL = 4, 6, 8     # save mid-window (position k=2 of 4)
tcfg = TrainConfig(policy=POLICY, mode="replicated",
                   hierarchy="two_level_async", local_steps=H,
                   error_feedback=True)
step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))

def run(state, start, stop):
    for i in range(start, stop):
        state, _ = step_fn(state, data.batch(i), jax.random.key(42))
    return state

state = init_state(model, mesh, tcfg, jax.random.key(0))
state = run(state, 0, SAVE_AT)
with tempfile.TemporaryDirectory() as tmp:
    path = os.path.join(tmp, "mid.npz")
    save_checkpoint(path, state, step=int(state.step))
    full = run(state, SAVE_AT, TOTAL)
    # a FRESH state tree restored strictly from the mid-window snapshot
    like = jax.eval_shape(
        lambda k: init_state(model, mesh, tcfg, k), jax.random.key(0))
    like = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                  like)
    restored, step = load_checkpoint(path, like=like)
assert step == SAVE_AT and int(restored.step) == SAVE_AT
resumed = run(restored, SAVE_AT, TOTAL)
df = digest((full.params, full.opt, full.ef, full.outer))
dr = digest((resumed.params, resumed.opt, resumed.ef, resumed.outer))
assert df == dr, (df, dr)
print("MID-WINDOW-RESUME OK", df[:12])
""")


def test_async_traced_collective_split():
    """The temporal claim, pinned on the jaxprs themselves: the inner
    step traces ZERO wire collectives (no all_to_all/all_gather/
    reduce_scatter/psum_scatter on ANY axis — its only collectives are
    psum means), while the sync step runs its quantized all_to_all on
    the pod (DCN) axis ONLY, bracketed by intra scatter/gather."""
    run_devices(COMMON + """
from repro.utils.jaxpr import axis_collectives, collective_axis_counts

tcfg = TrainConfig(policy=POLICY, mode="replicated",
                   hierarchy="two_level_async", local_steps=4,
                   error_feedback=True)
state = jax.eval_shape(lambda k: init_state(model, mesh, tcfg, k),
                       jax.random.key(0))
step_fn, _ = make_train_step(model, mesh, tcfg, constant_lr(0.05))
batch = data.batch(0)

inner = collective_axis_counts(
    jax.make_jaxpr(step_fn.inner_fn)(state, batch, jax.random.key(1)))
wire = ("all_to_all", "all_gather", "reduce_scatter", "psum_scatter")
for (p, ax), cnt in inner.items():
    assert p not in wire, (p, ax, cnt)
assert any(p == "psum" for (p, ax) in inner), inner

sync = collective_axis_counts(
    jax.make_jaxpr(step_fn.sync_fn)(state, batch, jax.random.key(1)))
# one quantized group (default=orq-9): 2 a2a (words + levels) on pod
assert axis_collectives(sync, "all_to_all", ("pod",)) == 2, sync
for (p, ax), cnt in sync.items():
    if p == "all_to_all":
        assert ax == ("pod",), (p, ax, cnt)   # DCN only, ever
print("TRACE-SPLIT OK inner:", dict(inner))
print("TRACE-SPLIT OK sync a2a(pod):", 2)
""")
