"""Tests for the trace auditor (``repro.analysis``).

Covers: one true positive per registered rule (the seeded-violation
corpus), zero lint findings on the real tree, lint exemptions, the
shared sub-jaxpr traversal (custom_vjp fwd / while bodies), engine
registry semantics, the committed ``benchmarks/ANALYSIS.json``
coverage snapshot, and the ``python -m repro.analysis`` CLI contract
(``--check`` exit codes, ``--inject-violation``, ``--selftest``).
"""
import ast
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import CHECKS, SourceBundle, TraceBundle, run_checks
from repro.analysis import register_check
from repro.analysis import lint, rules
from repro.analysis.engine import SourceFile
from repro.analysis.selftest import seeded_bundle, run_selftest
from repro.analysis.traversal import walk_eqns

ROOT = Path(__file__).resolve().parents[1]


def _src(path: str, text: str) -> SourceBundle:
    return SourceBundle(label="test", files=(
        SourceFile(path=path, text=text,
                   tree=ast.parse(text, filename=path)),))


# ---------------------------------------------------------------- rules


class TestTruePositives:
    """Every registered rule must fire on its seeded violation —
    the same corpus ``--selftest`` runs in CI."""

    @pytest.mark.parametrize("rule", sorted(CHECKS))
    def test_rule_fires_on_seed(self, rule):
        findings = run_checks([seeded_bundle(rule)], rules=[rule])
        assert findings, f"rule {rule!r} silent on its seeded violation"
        assert all(f.rule == rule for f in findings)
        for f in findings:
            d = f.to_dict()
            assert d["rule"] == rule and d["message"]

    def test_run_selftest_covers_every_rule(self):
        res = run_selftest()
        assert set(res) == set(CHECKS)
        assert all(res[r] for r in res)

    def test_seeded_bundle_unknown_rule(self):
        with pytest.raises(KeyError):
            seeded_bundle("no-such-rule")


class TestEngine:
    def test_registry_has_trace_and_source_rules(self):
        kinds = {c.kind for c in CHECKS.values()}
        assert kinds == {"trace", "source"}
        assert all(c.protects for c in CHECKS.values())

    def test_duplicate_rule_id_raises(self):
        existing = next(iter(CHECKS))
        with pytest.raises(ValueError, match="duplicate"):
            register_check(existing, kind="trace")(lambda b: [])

    def test_bad_kind_raises(self):
        with pytest.raises(ValueError, match="kind"):
            register_check("x", kind="hlo")

    def test_unknown_rule_selection_raises(self):
        with pytest.raises(KeyError, match="no-such-rule"):
            run_checks([], rules=["no-such-rule"])

    def test_source_rules_skip_trace_bundles(self):
        import jax
        import jax.numpy as jnp

        closed = jax.make_jaxpr(lambda x: x + 1.0)(jnp.ones(4))
        bundle = TraceBundle(label="t", kind="wire_op", closed=closed)
        src_rules = [r for r, c in CHECKS.items() if c.kind == "source"]
        assert run_checks([bundle], rules=src_rules) == []

    def test_vmem_budget_matches_kernel_constant(self):
        from repro.kernels import fused_encode

        assert rules.DEFAULT_VMEM_BUDGET == fused_encode.VMEM_TILE_BYTES


# ----------------------------------------------------------------- lint


class TestLint:
    def test_real_tree_is_clean(self):
        findings = run_checks([lint.collect_sources()])
        assert findings == [], "\n".join(str(f) for f in findings)

    def test_env_accessor_file_is_exempt(self):
        text = "import os\nFLAG = os.environ.get('REPRO_USE_KERNELS')\n"
        assert run_checks([_src("repro/utils/env.py", text)],
                          rules=["env-read"]) == []
        hits = run_checks([_src("repro/train/step.py", text)],
                          rules=["env-read"])
        assert len(hits) == 1 and "repro.utils.env" in hits[0].message

    def test_set_axis_names_allows_tuples(self):
        ok = "def f(r, x):\n    return r(x, axis_names=('pod', 'data'))\n"
        bad = "def f(r, x):\n    return r(x, axis_names={'pod', 'data'})\n"
        assert run_checks([_src("repro/core/comm/a.py", ok)],
                          rules=["set-axis-names"]) == []
        assert run_checks([_src("repro/core/comm/a.py", bad)],
                          rules=["set-axis-names"])

    def test_pallas_body_allows_plain_jnp(self):
        text = (
            "import jax.numpy as jnp\n"
            "from jax.experimental import pallas as pl\n"
            "\n"
            "def _kernel(x_ref, o_ref):\n"
            "    o_ref[...] = jnp.maximum(x_ref[...], 0.0)\n"
            "\n"
            "def op(x):\n"
            "    return pl.pallas_call(_kernel, out_shape=x)(x)\n")
        assert run_checks([_src("repro/kernels/relu.py", text)],
                          rules=["pallas-body-discipline"]) == []

    def test_registry_bypass_exempts_registry(self):
        text = ("from repro.core.quantizers import Quantizer\n"
                "q = Quantizer(bucket_size=8, method='orq', num_levels=9)\n")
        assert run_checks([_src("repro/core/api.py", text)],
                          rules=["registry-bypass"]) == []
        assert run_checks([_src("repro/launch/perf.py", text)],
                          rules=["registry-bypass"])


# ------------------------------------------------------------ traversal


class TestTraversal:
    def test_custom_vjp_fwd_body_is_opt_in(self):
        import jax
        import jax.numpy as jnp

        @jax.custom_vjp
        def f(x):
            return x * 2.0

        def fwd(x):
            return x * 2.0, jnp.sin(x)   # sin lives ONLY in the fwd rule

        def bwd(res, g):
            return (g * res,)

        f.defvjp(fwd, bwd)
        closed = jax.make_jaxpr(f)(jnp.ones(4))

        def prims(**kw):
            return [e.primitive.name for e, _ in walk_eqns(closed, **kw)]

        assert "sin" not in prims()
        assert "sin" in prims(include_custom_vjp_fwd=True)

    def test_while_body_is_reachable(self):
        import jax
        import jax.numpy as jnp
        from jax import lax

        def f(x):
            return lax.while_loop(lambda c: c[0] < 3,
                                  lambda c: (c[0] + 1, jnp.sin(c[1])),
                                  (0, x))

        closed = jax.make_jaxpr(f)(jnp.ones(4))
        hits = [(e, path) for e, path in walk_eqns(closed)
                if e.primitive.name == "sin"]
        assert hits and "while" in hits[0][1]


# ----------------------------------------------------- coverage snapshot


class TestAnalysisSnapshot:
    def test_committed_snapshot_matches_registry(self):
        snap = json.loads((ROOT / "benchmarks/ANALYSIS.json").read_text())
        assert snap["schema"] == 1
        assert snap["n_findings"] == 0
        assert snap["selftest_ok"] is True
        assert snap["n_bundles"] >= 60
        assert {r["rule"] for r in snap["rules"]} == set(CHECKS), (
            "benchmarks/ANALYSIS.json is stale — regenerate with "
            "PYTHONPATH=src:. python benchmarks/analysis.py "
            "--update-baseline")

    def test_coverage_gate_flags_regressions(self):
        from benchmarks.analysis import check

        base = {"schema": 1, "n_findings": 0, "n_bundles": 66,
                "selftest_ok": True,
                "rules": [{"rule": r} for r in CHECKS]}
        assert check(dict(base), base) == []
        worse = dict(base, n_findings=2, n_bundles=10,
                     rules=[{"rule": "collective-budget"}],
                     selftest_ok=False)
        fails = check(worse, base)
        assert len(fails) == 4  # findings, selftest, lost rules, shrink


# -------------------------------------------------------------- the CLI


def _cli(*argv, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=timeout)


class TestCli:
    def test_list_rules(self):
        r = _cli("--list-rules")
        assert r.returncode == 0
        for rule in CHECKS:
            assert rule in r.stdout

    def test_check_lint_and_wire_clean(self):
        r = _cli("--check", "--no-train", "--no-serve")
        assert r.returncode == 0, r.stdout + r.stderr
        assert "0 findings" in r.stdout

    def test_inject_violation_fails_check(self):
        r = _cli("--check", "--no-wire", "--no-train", "--no-serve",
                 "--no-lint", "--inject-violation", "donation")
        assert r.returncode == 1, r.stdout + r.stderr
        assert "donation" in r.stdout

    def test_selftest_passes(self):
        r = _cli("--selftest")
        assert r.returncode == 0, r.stdout + r.stderr

    @pytest.mark.slow
    def test_full_matrix_check_and_json(self, tmp_path):
        out = tmp_path / "report.json"
        r = _cli("--check", "--json", str(out))
        assert r.returncode == 0, r.stdout + r.stderr
        rep = json.loads(out.read_text())
        assert rep["schema"] == 1 and rep["n_findings"] == 0
        labels = {b["label"] for b in rep["bundles"]}
        assert any(l.startswith("train/fsdp/two_level") for l in labels)
        assert any(l.startswith("serve/") for l in labels)
